"""Unit tests for the fault-tolerant runtime substrate (PR 6).

Covers the pieces under ``repro.core.resilience`` and the satellite
hardening: checkpoint serialization (atomic, crc-checked, versioned),
input validation at the solver boundary, the numerical guard reduction,
failure classification for the degradation ladder, grid-search probe
retries, and the crc-stamped autotune cache store.
"""
import json
import os
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import resilience
from repro.core.policy import grid_search, probe_error_is_retryable
from repro.core.sparse_tensor import SparseTensor, random_poisson_tensor
from repro.perf.autotune import AutotuneCache
from repro.testing import faults


# ---------------------------------------------------------------------------
# Checkpoint format
# ---------------------------------------------------------------------------


def _state():
    return {
        "fingerprint": "abc123",
        "outer": 7,
        "kkt_history": [0.5, 0.25],
        "strategies": ["segment", "blocked"],
        "lam": jnp.asarray([1.0, 2.0, 3.0], jnp.float32),
        "factors": [jnp.ones((4, 3), jnp.float32),
                    jnp.full((5, 3), 2.0, jnp.float32)],
    }


def test_checkpoint_roundtrip(tmp_path):
    path = str(tmp_path / "ck.npz")
    resilience.save_checkpoint(path, _state())
    out = resilience.load_checkpoint(path)
    assert out["fingerprint"] == "abc123"
    assert out["outer"] == 7
    assert out["kkt_history"] == [0.5, 0.25]
    assert out["strategies"] == ["segment", "blocked"]
    np.testing.assert_array_equal(out["lam"], [1.0, 2.0, 3.0])
    assert len(out["factors"]) == 2
    np.testing.assert_array_equal(out["factors"][1],
                                  np.full((5, 3), 2.0, np.float32))


def test_checkpoint_write_is_atomic(tmp_path):
    """No partial file is left behind: the tmp file is renamed over the
    target, so a concurrent reader sees either the old or the new
    checkpoint, never a torn one."""
    path = str(tmp_path / "ck.npz")
    resilience.save_checkpoint(path, _state())
    first = open(path, "rb").read()
    st = _state()
    st["outer"] = 8
    resilience.save_checkpoint(path, st)
    assert resilience.load_checkpoint(path)["outer"] == 8
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert leftovers == []
    assert open(path, "rb").read() != first


@pytest.mark.parametrize("kind", ["flip", "truncate", "magic"])
def test_checkpoint_corruption_detected(tmp_path, kind):
    path = str(tmp_path / "ck.npz")
    resilience.save_checkpoint(path, _state())
    faults.corrupt_checkpoint(path, kind=kind)
    with pytest.raises(resilience.CheckpointError):
        resilience.load_checkpoint(path)


def test_checkpoint_quarantine(tmp_path):
    path = str(tmp_path / "ck.npz")
    resilience.save_checkpoint(path, _state())
    q = resilience.quarantine_checkpoint(path)
    assert q == path + ".corrupt"
    assert os.path.exists(q) and not os.path.exists(path)


def test_checkpoint_schema_gate(tmp_path):
    """A future-schema checkpoint is refused, not misparsed."""
    path = str(tmp_path / "ck.npz")
    resilience.save_checkpoint(path, _state())
    blob = open(path, "rb").read()
    n = len(resilience._MAGIC)
    hlen = int.from_bytes(blob[n:n + 8], "big")
    header = json.loads(blob[n + 8:n + 8 + hlen])
    header["schema"] = resilience.CHECKPOINT_SCHEMA + 1
    hb = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(resilience._MAGIC + len(hb).to_bytes(8, "big") + hb
                + blob[n + 8 + hlen:])
    with pytest.raises(resilience.CheckpointError, match="schema"):
        resilience.load_checkpoint(path)


def test_config_fingerprint_is_stable():
    a = resilience.config_fingerprint({"rank": 4, "tol": 1e-4})
    b = resilience.config_fingerprint({"tol": 1e-4, "rank": 4})
    c = resilience.config_fingerprint({"rank": 5, "tol": 1e-4})
    assert a == b != c


# ---------------------------------------------------------------------------
# Input validation at the solver boundary
# ---------------------------------------------------------------------------


def _tensor(idx, vals, shape=(4, 3, 2)):
    return SparseTensor(shape=shape,
                        indices=jnp.asarray(idx, jnp.int32),
                        values=jnp.asarray(vals, jnp.float32))


GOOD_IDX = np.array([[0, 0, 0], [3, 2, 1], [1, 1, 1]])
GOOD_VALS = np.array([1.0, 2.0, 3.0])


@pytest.mark.parametrize("rank", [0, -1, 2.5])
def test_validate_rejects_bad_rank(rank):
    t = _tensor(GOOD_IDX, GOOD_VALS)
    with pytest.raises(ValueError, match="rank must be a positive integer"):
        resilience.validate_decomposition_inputs(t, rank)


def test_validate_rejects_out_of_range_index_naming_mode():
    idx = GOOD_IDX.copy()
    idx[1, 1] = 3  # mode 1 has dim 3: valid rows are 0..2
    with pytest.raises(ValueError,
                       match=r"mode 1 has out-of-range index 3 at nonzero 1"):
        resilience.validate_decomposition_inputs(_tensor(idx, GOOD_VALS), 2)


def test_validate_rejects_nonfinite_and_negative_values():
    with pytest.raises(ValueError, match="non-finite"):
        resilience.validate_decomposition_inputs(
            _tensor(GOOD_IDX, [1.0, np.nan, 2.0]), 2)
    with pytest.raises(ValueError, match="negative"):
        resilience.validate_decomposition_inputs(
            _tensor(GOOD_IDX, [1.0, -2.0, 2.0]), 2)
    # negative allowed when nonneg=False (a least-squares caller)
    resilience.validate_decomposition_inputs(
        _tensor(GOOD_IDX, [1.0, -2.0, 2.0]), 2, nonneg=False)


def test_solver_boundaries_validate():
    from repro.core import cp_als, cpapr_mu

    idx = GOOD_IDX.copy()
    idx[0, 2] = 9
    t = _tensor(idx, GOOD_VALS)
    with pytest.raises(ValueError, match="cpapr_mu: mode 2"):
        cpapr_mu(t, 2)
    with pytest.raises(ValueError, match="cp_als: mode 2"):
        cp_als(t, 2, n_iters=1)
    with pytest.raises(ValueError, match="cpapr_mu: rank"):
        cpapr_mu(_tensor(GOOD_IDX, GOOD_VALS), -3)


# ---------------------------------------------------------------------------
# Numerical guard
# ---------------------------------------------------------------------------


def test_guard_ok_states():
    good = jnp.ones((3, 2))
    lam = jnp.ones((2,))
    assert bool(resilience.guard_ok(good, lam))
    assert not bool(resilience.guard_ok(good.at[0, 0].set(jnp.nan), lam))
    assert not bool(resilience.guard_ok(good.at[1, 1].set(jnp.inf), lam))
    assert not bool(resilience.guard_ok(good.at[2, 0].set(-1.0), lam))
    assert not bool(resilience.guard_ok(good, lam.at[0].set(jnp.nan)))
    assert not bool(resilience.guard_ok(good, lam, viol=jnp.float32(jnp.nan)))
    assert bool(resilience.guard_ok(good, lam, viol=jnp.float32(0.5)))
    assert resilience.state_ok(good, lam) is True


# ---------------------------------------------------------------------------
# Failure classification (the ladder's dispatch table)
# ---------------------------------------------------------------------------


def test_classify_failure_mapping():
    cf = resilience.classify_failure
    assert cf(MemoryError("boom")) == "oom"
    assert cf(RuntimeError("RESOURCE_EXHAUSTED: out of memory")) == "oom"
    assert cf(resilience.ShardAssignmentError("rb_start moved")) \
        == "fingerprint"
    assert cf(ValueError("unknown strategy 'warpspeed'")) == "policy"
    assert cf(RuntimeError("Mosaic lowering failed")) == "kernel"
    assert cf(NotImplementedError("pallas path")) == "kernel"
    assert cf(KeyError("nope")) is None
    assert cf(faults.KilledError("kill")) is None  # must propagate


# ---------------------------------------------------------------------------
# grid_search probe retries (satellite: no permanent inf for transients)
# ---------------------------------------------------------------------------


def _xla_error(msg="transient"):
    from jax._src.lib import xla_client

    return xla_client.XlaRuntimeError(msg)


def test_grid_search_retries_transient_probe():
    calls = {"n": 0}

    def flaky(p):
        calls["n"] += 1
        if calls["n"] == 1:
            raise _xla_error("INTERNAL: transient compile hiccup")
        return 0.5

    out = grid_search(flaky, [object()], retries=1, backoff=0.0)
    assert calls["n"] == 2
    (pol, secs, err), = out
    assert secs == 0.5 and err is None  # recovered: finite time, no error


def test_grid_search_does_not_retry_config_rejections():
    calls = {"n": 0}

    def bad(p):
        calls["n"] += 1
        raise ValueError("block_rows too large")

    out = grid_search(bad, [object()], retries=3, backoff=0.0)
    assert calls["n"] == 1  # deterministic rejection: one attempt only
    (pol, secs, err), = out
    assert secs == float("inf") and "retryable" not in err


def test_grid_search_tags_exhausted_retryables():
    def always(p):
        raise _xla_error("INTERNAL: persistent")

    (pol, secs, err), = grid_search(always, [object()], retries=1,
                                    backoff=0.0)
    assert secs == float("inf") and err.endswith("(retryable)")


def test_probe_error_classes():
    assert not probe_error_is_retryable(ValueError("x"))
    assert not probe_error_is_retryable(NotImplementedError("x"))
    assert probe_error_is_retryable(_xla_error())


# ---------------------------------------------------------------------------
# Autotune cache: crc stamping, corruption quarantine, concurrent writers
# ---------------------------------------------------------------------------


def _store_one(cache, key="k0", strategy="segment"):
    from repro.core.policy import PhiPolicy

    cache.store(key, PhiPolicy(strategy=strategy), 0.01, "grid")


def test_cache_roundtrip_has_crc(tmp_path):
    path = str(tmp_path / "cache.json")
    c = AutotuneCache(path)
    _store_one(c)
    data = json.load(open(path))
    assert isinstance(data.get("crc32"), str)
    c2 = AutotuneCache(path)
    assert c2.lookup("k0") is not None
    assert c2.n_crc_failures == 0


def test_cache_corrupt_body_loads_empty(tmp_path):
    path = str(tmp_path / "cache.json")
    c = AutotuneCache(path)
    _store_one(c)
    data = json.load(open(path))
    data["entries"]["k0"]["seconds"] = 99.0  # tampered body, stale crc
    json.dump(data, open(path, "w"))
    c2 = AutotuneCache(path)
    assert c2.entries == {} and c2.n_crc_failures == 1
    _store_one(c2, "k1")  # still usable: next save rewrites a valid file
    assert AutotuneCache(path).lookup("k1") is not None


def test_cache_legacy_file_without_crc_accepted(tmp_path):
    path = str(tmp_path / "cache.json")
    c = AutotuneCache(path)
    _store_one(c)
    data = json.load(open(path))
    del data["crc32"]
    json.dump(data, open(path, "w"))
    c2 = AutotuneCache(path)
    assert c2.lookup("k0") is not None and c2.n_crc_failures == 0


def test_cache_concurrent_writers_leave_valid_file(tmp_path):
    """N threads hammering store() on the same path must end with a
    parseable, crc-valid cache file (atomic rename: last writer wins,
    no interleaved torn writes)."""
    path = str(tmp_path / "cache.json")
    errs = []

    def writer(i):
        try:
            c = AutotuneCache(path)
            for j in range(5):
                _store_one(c, key=f"w{i}-{j}")
        except Exception as e:  # pragma: no cover - the failure under test
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errs == []
    final = AutotuneCache(path)
    assert final.n_crc_failures == 0
    assert len(final.entries) >= 5  # at least one writer's full batch


def test_heuristic_fallback_never_served_as_grid(tmp_path):
    """The inf-probe fix: a heuristic placeholder (nothing measured) is
    stored with seconds=None/source='heuristic' and must not satisfy a
    source='grid' lookup — a measuring tuner re-probes it instead of
    serving a winner that was never timed."""
    from repro.core.policy import PhiPolicy

    path = str(tmp_path / "cache.json")
    c = AutotuneCache(path)
    c.store("k0", PhiPolicy(strategy="segment"), float("inf"), "heuristic")
    assert c.lookup("k0", source="grid") is None
    assert c.lookup("k0") is not None
    assert json.load(open(path))["entries"]["k0"]["seconds"] is None


# ---------------------------------------------------------------------------
# RecoveryEvent bookkeeping
# ---------------------------------------------------------------------------


def test_recovery_event_roundtrips_through_checkpoint(tmp_path):
    import dataclasses

    ev = resilience.RecoveryEvent("demote_kernel", outer=3, mode=1,
                                  attempt=0, detail={"action": "a->b"})
    path = str(tmp_path / "ck.npz")
    st = _state()
    st["recoveries"] = [dataclasses.asdict(ev)]
    resilience.save_checkpoint(path, st)
    back = resilience.load_checkpoint(path)["recoveries"]
    assert resilience.RecoveryEvent(**back[0]) == ev
