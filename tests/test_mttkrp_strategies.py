"""MTTKRP / CP-ALS routed through the full strategy stack: cross-strategy
equivalence (scatter = segment = blocked = pallas = sharded = dense-f64
oracle) in-process and on 1/2/4 forced host devices, CP-ALS solver
equivalence across strategies + policy="auto", and the trace-count
regression for the hoisted jitted mode update."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    cp_als,
    krao_reduce_rows,
    mttkrp,
    mttkrp_mode,
    sort_mode,
)
from repro.core.layout import (
    build_blocked_layout,
    build_shard_pi_gather,
    shard_blocked_layout,
)
from repro.core.phi import ALL_PHI_STRATEGIES
from repro.core.pi import pi_rows
from repro.core.sparse_tensor import random_ktensor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def dense_mttkrp_reference(rows, vals, kr, n_rows):
    """Float64 numpy MTTKRP oracle: M[i] += x_j * kr_j."""
    rows = np.asarray(rows)
    vals = np.asarray(vals, np.float64)
    kr = np.asarray(kr, np.float64)
    out = np.zeros((n_rows, kr.shape[1]))
    np.add.at(out, rows, vals[:, None] * kr)
    return out


def _mode_problem(small_tensor, mode=0, bn=64, br=8):
    t, kt = small_tensor
    mv = sort_mode(t, mode)
    kr = pi_rows(mv.sorted_idx, kt.factors, mode)
    base = build_blocked_layout(np.asarray(mv.rows), mv.n_rows, bn, br)
    return t, kt, mv, kr, base


# ---------------------------------------------------------------------------
# Cross-strategy equivalence (single process; sharded runs emulated)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ALL_PHI_STRATEGIES)
@pytest.mark.parametrize("mode", [0, 1, 2])
def test_all_mttkrp_strategies_match_dense_reference(small_tensor, strategy,
                                                     mode):
    """Every MTTKRP path — unblocked, blocked, Pallas, sharded — pins to
    the same f64 numerics."""
    t, kt, mv, kr, base = _mode_problem(small_tensor, mode)
    ref = dense_mttkrp_reference(mv.rows, mv.sorted_vals, kr, mv.n_rows)
    layout = None
    if strategy in ("blocked", "pallas"):
        layout = base
    elif strategy == "sharded":
        layout = shard_blocked_layout(base, min(4, base.n_row_blocks))
    out = krao_reduce_rows(mv.rows, mv.sorted_vals, kr, mv.n_rows,
                           strategy=strategy, layout=layout)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-5, atol=1e-5)


@pytest.mark.parametrize("local_strategy", ["blocked", "pallas"])
def test_sharded_mttkrp_local_kr_matches_replicated(small_tensor,
                                                    local_strategy):
    """Shard-local Khatri-Rao (pi_gather) == precomputed-rows sharded path,
    bitwise, for both local compute flavours."""
    t, kt, mv, kr, base = _mode_problem(small_tensor)
    sl = shard_blocked_layout(base, 3)
    pig = build_shard_pi_gather(sl, np.asarray(mv.sorted_idx), 0)
    rep = krao_reduce_rows(mv.rows, mv.sorted_vals, kr, mv.n_rows,
                           strategy="sharded", layout=sl,
                           local_strategy=local_strategy)
    loc = krao_reduce_rows(mv.rows, mv.sorted_vals, None, mv.n_rows,
                           strategy="sharded", layout=sl,
                           local_strategy=local_strategy,
                           pi_gather=pig, factors=kt.factors)
    np.testing.assert_allclose(np.asarray(loc), np.asarray(rep),
                               rtol=1e-6, atol=1e-7)


def test_mttkrp_wrapper_and_mode_view_agree(small_tensor):
    """Legacy mttkrp(indices, ...) == mttkrp_mode(ModeView, ...) == oracle,
    and the unsorted scatter path still accepts raw COO order."""
    t, kt, mv, kr, base = _mode_problem(small_tensor)
    ref = dense_mttkrp_reference(mv.rows, mv.sorted_vals, kr, mv.n_rows)
    legacy = mttkrp(t.indices, t.values, tuple(kt.factors), 0, t.shape[0],
                    strategy="scatter")
    np.testing.assert_allclose(np.asarray(legacy), ref, rtol=3e-5, atol=1e-5)
    via_mv = mttkrp_mode(mv, kt.factors, strategy="blocked", layout=base)
    np.testing.assert_allclose(np.asarray(via_mv), ref, rtol=3e-5, atol=1e-5)


def test_krao_sharded_falls_back_when_too_few_row_blocks(small_tensor,
                                                         monkeypatch):
    """Sharded MTTKRP with more shards than row blocks warns and falls
    back to the single-device blocked path (mirrors the Phi behaviour)."""
    import warnings

    t, kt, mv, kr, _ = _mode_problem(small_tensor)
    monkeypatch.setattr("repro.core.phi._default_shard_count",
                        lambda mesh: 4096)
    ref = dense_mttkrp_reference(mv.rows, mv.sorted_vals, kr, mv.n_rows)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = krao_reduce_rows(mv.rows, mv.sorted_vals, kr, mv.n_rows,
                               strategy="sharded")
    assert any("falling back" in str(x.message) for x in w)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# CP-ALS solver equivalence across the stack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["segment", "blocked", "pallas",
                                      "sharded"])
def test_cp_als_strategies_match_scatter(small_tensor, strategy):
    t, kt = small_tensor
    init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
    kt0, fits0 = cp_als(t, 4, n_iters=3, init=init, strategy="scatter")
    kt1, fits1 = cp_als(t, 4, n_iters=3, init=init, strategy=strategy,
                        n_shards=3)
    np.testing.assert_allclose(fits1, fits0, rtol=2e-4, atol=2e-5)
    for a, b in zip(kt0.factors, kt1.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_cp_als_auto_policy_uses_tuner(small_tensor, tmp_path):
    """policy='auto' consults the same persistent autotuner as CP-APR —
    entries appear in the store and the fit matches the scatter run."""
    from repro.perf.autotune import Autotuner

    t, kt = small_tensor
    init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
    tuner = Autotuner(cache_path=str(tmp_path / "c.json"), measure=False)
    kt0, fits0 = cp_als(t, 4, n_iters=2, init=init, strategy="scatter")
    kt1, fits1 = cp_als(t, 4, n_iters=2, init=init, policy="auto",
                        autotuner=tuner)
    np.testing.assert_allclose(fits1, fits0, rtol=2e-4, atol=2e-5)
    assert len(tuner.cache.entries) == t.ndim  # one v2 key per mode
    # a second run hits the cache, no further searches
    tuner2 = Autotuner(cache_path=str(tmp_path / "c.json"), measure=False)
    cp_als(t, 4, n_iters=1, init=init, policy="auto", autotuner=tuner2)
    assert tuner2.n_hits == t.ndim and tuner2.n_searches == 0


def test_cp_als_mode_updates_trace_once(small_tensor):
    """The hoisted jitted mode update traces exactly once per mode across
    many iterations — the re-trace regression this PR fixes (the per-mode
    Python loop used to rebuild work per call)."""
    import repro.core.cpapr as cpapr_mod  # hoisted_mode_inputs lives here

    t, kt = small_tensor
    init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
    traces = []
    real_pi_rows = cpapr_mod.pi_rows

    def counting_pi_rows(idx, factors, n):
        traces.append(n)  # runs at trace time only (inside jax.jit)
        return real_pi_rows(idx, factors, n)

    try:
        cpapr_mod.pi_rows = counting_pi_rows
        cp_als(t, 4, n_iters=5, init=init, strategy="segment")
    finally:
        cpapr_mod.pi_rows = real_pi_rows
    # one trace per mode, regardless of iteration count
    assert sorted(traces) == list(range(t.ndim)), traces


# ---------------------------------------------------------------------------
# Real-mesh equivalence on 1/2/4 forced host devices (subprocess)
# ---------------------------------------------------------------------------


def _run(script: str, devices: int, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


MTTKRP_EQUIV_SCRIPT = """
import jax, numpy as np
from repro.core.sparse_tensor import random_poisson_tensor, sort_mode
from repro.core.pi import pi_rows
from repro.core.layout import (build_blocked_layout, shard_blocked_layout,
                               build_shard_pi_gather)
from repro.core.phi import krao_reduce_rows
from repro.core.distributed import make_phi_mesh

n_dev = jax.device_count()
assert n_dev == {devices}, n_dev
t, kt = random_poisson_tensor(jax.random.PRNGKey(0), (40, 30, 25),
                              nnz=1500, rank=4)
for mode in range(t.ndim):
    mv = sort_mode(t, mode)
    kr = pi_rows(mv.sorted_idx, kt.factors, mode)
    rows = np.asarray(mv.rows)
    vals = np.asarray(mv.sorted_vals, np.float64)
    dense = np.zeros((mv.n_rows, 4))
    np.add.at(dense, rows, vals[:, None] * np.asarray(kr, np.float64))

    base = build_blocked_layout(rows, mv.n_rows, 64, 8)
    sl = shard_blocked_layout(base, n_dev)
    pig = build_shard_pi_gather(sl, np.asarray(mv.sorted_idx), mode)
    mesh = make_phi_mesh(n_dev) if n_dev > 1 else None
    cases = [
        ("scatter", None, None, False), ("segment", None, None, False),
        ("blocked", base, None, False), ("pallas", base, None, False),
        ("sharded", sl, mesh, False), ("sharded", sl, mesh, True),
    ]
    for strategy, layout, m, local_kr in cases:
        out = krao_reduce_rows(
            mv.rows, mv.sorted_vals, None if local_kr else kr, mv.n_rows,
            strategy=strategy, layout=layout, mesh=m,
            pi_gather=pig if local_kr else None,
            factors=kt.factors if local_kr else None)
        np.testing.assert_allclose(
            np.asarray(out), dense, rtol=3e-5, atol=1e-5,
            err_msg=f"{{strategy}} local_kr={{local_kr}} mode {{mode}}")
print("MTTKRP_EQUIV_OK")
"""


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_mttkrp_cross_strategy_equivalence_forced_devices(devices):
    """scatter = segment = blocked = pallas = sharded (replicated and
    shard-local Khatri-Rao) = dense reference on 1/2/4 forced host devices
    (real mesh + psum whenever devices > 1)."""
    assert "MTTKRP_EQUIV_OK" in _run(
        MTTKRP_EQUIV_SCRIPT.format(devices=devices), devices)


CPALS_MESH_SCRIPT = """
import jax, numpy as np
from repro.core import cp_als
from repro.core.sparse_tensor import random_poisson_tensor, random_ktensor
from repro.core.distributed import make_phi_mesh

assert jax.device_count() == 4
t, _ = random_poisson_tensor(jax.random.PRNGKey(0), (40, 30, 25),
                             nnz=1500, rank=4)
init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
kt0, fits0 = cp_als(t, 4, n_iters=2, init=init, strategy="scatter")
kt1, fits1 = cp_als(t, 4, n_iters=2, init=init, strategy="sharded",
                    mesh=make_phi_mesh(4))
np.testing.assert_allclose(fits1, fits0, rtol=2e-4, atol=2e-5)
for a, b in zip(kt0.factors, kt1.factors):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
print("CPALS_MESH_OK")
"""


def test_cp_als_sharded_real_mesh():
    """Full CP-ALS under a real 4-device mesh matches the scatter run."""
    assert "CPALS_MESH_OK" in _run(CPALS_MESH_SCRIPT, devices=4)
