"""MTTKRP / CP-ALS routed through the full strategy stack: shard-local
Khatri-Rao equivalence, CP-ALS solver equivalence across strategies +
policy="auto", and the trace-count regression for the hoisted jitted
mode update.  (The cross-strategy dense-f64 oracle matrix — in-process
and on 1/2/4 forced host devices — lives in the registry-driven
tests/test_conformance.py.)"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    cp_als,
    krao_reduce_rows,
    mttkrp,
    mttkrp_mode,
    sort_mode,
)
from repro.core.layout import (
    build_blocked_layout,
    build_shard_pi_gather,
    shard_blocked_layout,
)
from repro.core.pi import pi_rows
from repro.core.sparse_tensor import random_ktensor

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def dense_mttkrp_reference(rows, vals, kr, n_rows):
    """Float64 numpy MTTKRP oracle: M[i] += x_j * kr_j."""
    rows = np.asarray(rows)
    vals = np.asarray(vals, np.float64)
    kr = np.asarray(kr, np.float64)
    out = np.zeros((n_rows, kr.shape[1]))
    np.add.at(out, rows, vals[:, None] * kr)
    return out


def _mode_problem(small_tensor, mode=0, bn=64, br=8):
    t, kt = small_tensor
    mv = sort_mode(t, mode)
    kr = pi_rows(mv.sorted_idx, kt.factors, mode)
    base = build_blocked_layout(np.asarray(mv.rows), mv.n_rows, bn, br)
    return t, kt, mv, kr, base


# ---------------------------------------------------------------------------
# Cross-strategy equivalence (single process; sharded runs emulated)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("local_strategy", ["blocked", "pallas"])
def test_sharded_mttkrp_local_kr_matches_replicated(small_tensor,
                                                    local_strategy):
    """Shard-local Khatri-Rao (pi_gather) == precomputed-rows sharded path,
    bitwise, for both local compute flavours."""
    t, kt, mv, kr, base = _mode_problem(small_tensor)
    sl = shard_blocked_layout(base, 3)
    pig = build_shard_pi_gather(sl, np.asarray(mv.sorted_idx), 0)
    rep = krao_reduce_rows(mv.rows, mv.sorted_vals, kr, mv.n_rows,
                           strategy="sharded", layout=sl,
                           local_strategy=local_strategy)
    loc = krao_reduce_rows(mv.rows, mv.sorted_vals, None, mv.n_rows,
                           strategy="sharded", layout=sl,
                           local_strategy=local_strategy,
                           pi_gather=pig, factors=kt.factors)
    np.testing.assert_allclose(np.asarray(loc), np.asarray(rep),
                               rtol=1e-6, atol=1e-7)


def test_mttkrp_wrapper_and_mode_view_agree(small_tensor):
    """Legacy mttkrp(indices, ...) == mttkrp_mode(ModeView, ...) == oracle,
    and the unsorted scatter path still accepts raw COO order."""
    t, kt, mv, kr, base = _mode_problem(small_tensor)
    ref = dense_mttkrp_reference(mv.rows, mv.sorted_vals, kr, mv.n_rows)
    legacy = mttkrp(t.indices, t.values, tuple(kt.factors), 0, t.shape[0],
                    strategy="scatter")
    np.testing.assert_allclose(np.asarray(legacy), ref, rtol=3e-5, atol=1e-5)
    via_mv = mttkrp_mode(mv, kt.factors, strategy="blocked", layout=base)
    np.testing.assert_allclose(np.asarray(via_mv), ref, rtol=3e-5, atol=1e-5)


def test_krao_sharded_falls_back_when_too_few_row_blocks(small_tensor,
                                                         monkeypatch):
    """Sharded MTTKRP with more shards than row blocks warns and falls
    back to the single-device blocked path (mirrors the Phi behaviour)."""
    import warnings

    t, kt, mv, kr, _ = _mode_problem(small_tensor)
    monkeypatch.setattr("repro.core.phi._default_shard_count",
                        lambda mesh: 4096)
    ref = dense_mttkrp_reference(mv.rows, mv.sorted_vals, kr, mv.n_rows)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = krao_reduce_rows(mv.rows, mv.sorted_vals, kr, mv.n_rows,
                               strategy="sharded")
    assert any("falling back" in str(x.message) for x in w)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# CP-ALS solver equivalence across the stack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["segment", "blocked", "pallas",
                                      "sharded"])
def test_cp_als_strategies_match_scatter(small_tensor, strategy):
    t, kt = small_tensor
    init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
    kt0, fits0 = cp_als(t, 4, n_iters=3, init=init, strategy="scatter")
    kt1, fits1 = cp_als(t, 4, n_iters=3, init=init, strategy=strategy,
                        n_shards=3)
    np.testing.assert_allclose(fits1, fits0, rtol=2e-4, atol=2e-5)
    for a, b in zip(kt0.factors, kt1.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_cp_als_auto_policy_uses_tuner(small_tensor, tmp_path):
    """policy='auto' consults the same persistent autotuner as CP-APR —
    entries appear in the store and the fit matches the scatter run."""
    from repro.perf.autotune import Autotuner

    t, kt = small_tensor
    init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
    tuner = Autotuner(cache_path=str(tmp_path / "c.json"), measure=False)
    kt0, fits0 = cp_als(t, 4, n_iters=2, init=init, strategy="scatter")
    kt1, fits1 = cp_als(t, 4, n_iters=2, init=init, policy="auto",
                        autotuner=tuner)
    np.testing.assert_allclose(fits1, fits0, rtol=2e-4, atol=2e-5)
    assert len(tuner.cache.entries) == t.ndim  # one v2 key per mode
    # a second run hits the cache, no further searches
    tuner2 = Autotuner(cache_path=str(tmp_path / "c.json"), measure=False)
    cp_als(t, 4, n_iters=1, init=init, policy="auto", autotuner=tuner2)
    assert tuner2.n_hits == t.ndim and tuner2.n_searches == 0


def test_cp_als_mode_updates_trace_once(small_tensor):
    """The hoisted jitted mode update traces exactly once per mode across
    many iterations — the re-trace regression this PR fixes (the per-mode
    Python loop used to rebuild work per call)."""
    import repro.core.cpapr as cpapr_mod  # hoisted_mode_inputs lives here

    t, kt = small_tensor
    init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
    traces = []
    real_pi_rows = cpapr_mod.pi_rows

    def counting_pi_rows(idx, factors, n):
        traces.append(n)  # runs at trace time only (inside jax.jit)
        return real_pi_rows(idx, factors, n)

    try:
        cpapr_mod.pi_rows = counting_pi_rows
        cp_als(t, 4, n_iters=5, init=init, strategy="segment")
    finally:
        cpapr_mod.pi_rows = real_pi_rows
    # one trace per mode, regardless of iteration count
    assert sorted(traces) == list(range(t.ndim)), traces


# ---------------------------------------------------------------------------
# Real-mesh equivalence on 1/2/4 forced host devices (subprocess)
# ---------------------------------------------------------------------------


def _run(script: str, devices: int, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


CPALS_MESH_SCRIPT = """
import jax, numpy as np
from repro.core import cp_als
from repro.core.sparse_tensor import random_poisson_tensor, random_ktensor
from repro.core.distributed import make_phi_mesh

assert jax.device_count() == 4
t, _ = random_poisson_tensor(jax.random.PRNGKey(0), (40, 30, 25),
                             nnz=1500, rank=4)
init = random_ktensor(jax.random.PRNGKey(1), t.shape, 4)
kt0, fits0 = cp_als(t, 4, n_iters=2, init=init, strategy="scatter")
kt1, fits1 = cp_als(t, 4, n_iters=2, init=init, strategy="sharded",
                    mesh=make_phi_mesh(4))
np.testing.assert_allclose(fits1, fits0, rtol=2e-4, atol=2e-5)
for a, b in zip(kt0.factors, kt1.factors):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)
print("CPALS_MESH_OK")
"""


def test_cp_als_sharded_real_mesh():
    """Full CP-ALS under a real 4-device mesh matches the scatter run."""
    assert "CPALS_MESH_OK" in _run(CPALS_MESH_SCRIPT, devices=4)
