"""Dense matrix-free tier: kernel parity, the analytic HLO bounds, the
fill-fraction policy cut, platform-aware default blocking, and the
itemsize-aware combine wire model.

The cross-strategy value conformance of the dense rows lives in
tests/test_conformance.py (``dense`` / ``dense-bf16`` registry rows);
this file covers what the registry matrix cannot: compiled-program
byte/FLOP accounting, the policy layer that *selects* the tier, and the
dtype-aware wire model the combine picker consults.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import can_force_host_devices

from repro.core.dense import DENSE_MAX_ELEMS, build_dense_mode, dense_kr_factors
from repro.core.layout import mode_run_stats
from repro.core.policy import DENSE_FILL_BIN_MAX, heuristic_policy
from repro.core.sparse_tensor import random_poisson_tensor, sort_mode
from repro.kernels.dense import mttkrp_dense, phi_dense
from repro.perf.hlo import (
    dense_input_bytes,
    dense_mttkrp_flops,
    dense_pad_dims,
    entry_parameter_bytes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RANK = 4


@pytest.fixture(scope="module")
def near_dense():
    """A small 4-way tensor dense enough for the tier (fill ~0.5) —
    4-way so the K axis really flattens two modes."""
    t, kt = random_poisson_tensor(jax.random.PRNGKey(2), (14, 10, 6, 4),
                                  nnz=1700, rank=RANK)
    return t, kt


# ---------------------------------------------------------------------------
# Kernel parity on a 4-way tensor (k_modes of length 2)
# ---------------------------------------------------------------------------


def _dense_oracle_mttkrp(t, factors, n):
    idx = np.asarray(t.indices)
    kr = np.ones((idx.shape[0], RANK))
    for m, f in enumerate(factors):
        if m != n:
            kr *= np.asarray(f, np.float64)[idx[:, m]]
    out = np.zeros((t.shape[n], RANK))
    np.add.at(out, idx[:, n], np.asarray(t.values, np.float64)[:, None] * kr)
    return out


def test_dense_mttkrp_4way_matches_oracle(near_dense):
    t, kt = near_dense
    for n in range(t.ndim):
        dn = build_dense_mode(np.asarray(t.indices), np.asarray(t.values),
                              t.shape, n)
        c, a = dense_kr_factors(dn, kt.factors)
        out = mttkrp_dense(dn.x, c, a)
        np.testing.assert_allclose(
            np.asarray(out, np.float64),
            _dense_oracle_mttkrp(t, kt.factors, n),
            rtol=3e-5, atol=1e-5, err_msg=f"mode {n}")


# ---------------------------------------------------------------------------
# Analytic FLOP / byte bounds vs the compiled program
# ---------------------------------------------------------------------------


def test_entry_parameter_bytes_match_analytic(near_dense):
    """The jitted dense entry points' compiled ENTRY parameters carry
    exactly the raw (K,I,J)+(J,R)+(K,R)[+(I,R)] operand bytes — padding
    must stay inside the program, never inflate the interface."""
    t, kt = near_dense
    dn = build_dense_mode(np.asarray(t.indices), np.asarray(t.values),
                          t.shape, 0)
    c, a = dense_kr_factors(dn, kt.factors)
    k, i, j = dn.x.shape

    txt = jax.jit(lambda x, cc, aa: mttkrp_dense(x, cc, aa)).lower(
        dn.x, c, a).compile().as_text()
    got = sum(entry_parameter_bytes(txt))
    assert got == dense_input_bytes(k, i, j, RANK), txt[:200]

    b = kt.factors[0] * kt.lam[None, :]
    txt = jax.jit(lambda x, cc, aa, bb: phi_dense(x, cc, aa, bb)).lower(
        dn.x, c, a, b).compile().as_text()
    got = sum(entry_parameter_bytes(txt))
    assert got == dense_input_bytes(k, i, j, RANK, with_b=True)


def test_padded_bound_dominates_raw():
    """The padded streaming bound dominates the raw interface bytes and
    the padded FLOP count dominates the algorithmic one (both collapse
    to equality on already-tile-aligned dims)."""
    for (k, i, j, r) in [(3, 14, 10, 4), (8, 8, 128, 128), (1, 1, 1, 1)]:
        raw = dense_input_bytes(k, i, j, r)
        padded = dense_input_bytes(k, i, j, r, padded=True)
        assert padded >= raw
        kp, ip, jp, rp = dense_pad_dims(k, i, j, r)
        assert dense_mttkrp_flops(kp, ip, jp, rp) >= \
            dense_mttkrp_flops(k, i, j, r)
    # aligned dims: padding is a no-op, bound is tight
    assert dense_input_bytes(8, 8, 128, 128, padded=True) == \
        dense_input_bytes(8, 8, 128, 128)
    # bf16 halves the bytes but doubles the sublane/block_k granularity
    assert dense_input_bytes(8, 16, 128, 128, itemsize=2) == \
        dense_input_bytes(8, 16, 128, 128) / 2


# ---------------------------------------------------------------------------
# The fill cut: policy layer selects the tier, with the cap honoured
# ---------------------------------------------------------------------------


def _stats_with_fill(nnz, n_rows, row_width):
    rng = np.random.default_rng(0)
    rows = np.sort(rng.integers(0, n_rows, nnz).astype(np.int32))
    return mode_run_stats(rows, n_rows, row_width=row_width)


@pytest.mark.parametrize("platform", ["cpu", "tpu"])
def test_fill_cut_selects_dense(platform):
    """fill > 2^-(DENSE_FILL_BIN_MAX+1) with the dense size under the cap
    -> the dense tier, on every platform."""
    stats = _stats_with_fill(nnz=1024, n_rows=32, row_width=64)  # fill 0.5
    assert stats.fill_bin <= DENSE_FILL_BIN_MAX
    pol = heuristic_policy(1024, 32, RANK, platform=platform, stats=stats)
    assert pol.strategy == "dense", pol


@pytest.mark.parametrize("platform", ["cpu", "tpu"])
def test_sparse_fill_stays_sparse(platform):
    stats = _stats_with_fill(nnz=1024, n_rows=256, row_width=4096)  # ~1e-3
    assert stats.fill_bin > DENSE_FILL_BIN_MAX
    pol = heuristic_policy(1024, 256, RANK, platform=platform, stats=stats)
    assert pol.strategy != "dense", pol


def test_fill_cut_honours_size_cap():
    """Near-dense but too big to materialize: the cut must refuse (the
    densified tensor would blow past DENSE_MAX_ELEMS)."""
    stats = _stats_with_fill(nnz=4096, n_rows=64, row_width=128)  # fill 0.5
    big = dataclasses.replace(
        stats, nnz=3 * DENSE_MAX_ELEMS // 4)  # cells = nnz/fill > cap
    pol = heuristic_policy(big.nnz, 64, RANK, platform="cpu", stats=big)
    assert pol.strategy != "dense", pol


def test_unknown_fill_never_dense():
    """Call sites without row_width leave fill unknown (-1): the cut must
    not fire on stale defaults."""
    rng = np.random.default_rng(1)
    rows = np.sort(rng.integers(0, 32, 1024).astype(np.int32))
    stats = mode_run_stats(rows, 32)  # no row_width
    assert stats.fill_bin == -1
    pol = heuristic_policy(1024, 32, RANK, platform="cpu", stats=stats)
    assert pol.strategy != "dense", pol


def test_build_dense_mode_refuses_over_cap():
    with pytest.raises(ValueError, match="max_elems"):
        build_dense_mode(np.zeros((1, 3), np.int32), np.ones(1),
                         (1 << 8, 1 << 8, 1 << 8), 0)


# ---------------------------------------------------------------------------
# Platform-aware default blocking (the _resolve_layout platform="tpu" fix)
# ---------------------------------------------------------------------------


def _hub_stats():
    """The conformance hub fixture's mode-0 stream (p95 dominated by the
    hub row): the case where CPU and TPU cache models disagree."""
    from test_conformance import make_fixture

    t, _ = make_fixture("hub")
    mv = sort_mode(t, 0)
    return int(np.asarray(mv.rows).shape[0]), mv.n_rows, \
        mode_run_stats(np.asarray(mv.rows), mv.n_rows)


def test_cpu_and_tpu_default_blockings_differ_on_hub():
    """Regression for the hardcoded platform="tpu" in the layout default:
    the CPU cache model (L2-budget, 2x p95 window) and the TPU VMEM
    model (4x, wider clip floor) must produce *different* block_nnz on
    the hub fixture — identical blockings would mean one platform is
    running the other's tuning."""
    nnz, n_rows, stats = _hub_stats()
    cpu = heuristic_policy(nnz, n_rows, RANK, platform="cpu", stats=stats)
    tpu = heuristic_policy(nnz, n_rows, RANK, platform="tpu", stats=stats)
    assert cpu.block_nnz != tpu.block_nnz, (cpu, tpu)


def test_resolve_layout_uses_real_backend():
    """phi_from_rows with no layout must build the *current* backend's
    default blocking (jax.default_backend()), not TPU's."""
    from repro.core.phi import _resolve_layout

    if jax.default_backend() != "cpu":
        pytest.skip("host backend is not cpu; cannot pin the expectation")
    from test_conformance import make_fixture

    t, kt = make_fixture("hub")
    mv = sort_mode(t, 0)
    pi = jnp.ones((np.asarray(mv.rows).shape[0], RANK), jnp.float32)
    layout, _, _ = _resolve_layout(mv.rows, mv.n_rows, None,
                                   mv.sorted_vals, pi, None, None)
    nnz, n_rows, stats = _hub_stats()
    cpu = heuristic_policy(nnz, n_rows, RANK, platform="cpu", stats=stats)
    tpu = heuristic_policy(nnz, n_rows, RANK, platform="tpu", stats=stats)
    assert layout.block_nnz == cpu.block_nnz
    assert layout.block_nnz != tpu.block_nnz


# ---------------------------------------------------------------------------
# Autotuner: the dense cut short-circuits probing
# ---------------------------------------------------------------------------


def test_autotuner_serves_dense_without_probes(tmp_path, near_dense):
    """A mode past the fill cut is served analytically: no measurement
    probes, result cached, cache hit on re-ask."""
    from repro.perf.autotune import Autotuner

    def no_measure(*a, **k):  # pragma: no cover - must never run
        raise AssertionError("dense cut must not probe")

    tuner = Autotuner(cache_path=str(tmp_path / "cache.json"),
                      measure=no_measure)
    nnz, n_rows = 1024, 32
    stats = _stats_with_fill(nnz=nnz, n_rows=n_rows, row_width=64)
    rng = np.random.default_rng(0)
    rows = jnp.asarray(np.sort(rng.integers(0, n_rows, nnz)).astype(np.int32))
    vals = jnp.ones((nnz,), jnp.float32)
    pi = jnp.ones((nnz, RANK), jnp.float32)
    b = jnp.ones((n_rows, RANK), jnp.float32)
    pol = tuner.policy_for_mode(rows, vals, pi, b, n_rows, RANK, stats=stats)
    assert pol.strategy == "dense"
    assert tuner.counters()["probes"] == 0
    pol2 = tuner.policy_for_mode(rows, vals, pi, b, n_rows, RANK, stats=stats)
    assert pol2.strategy == "dense"
    assert tuner.counters()["hits"] >= 1


# ---------------------------------------------------------------------------
# Itemsize-aware combine wire model (the 4-byte-element assumption fix)
# ---------------------------------------------------------------------------


def test_combine_wire_model_scales_with_itemsize():
    """f64 factors double every byte figure the combine picker consults;
    the effective_mode_combine plumbing accepts the itemsize."""
    from test_conformance import BN, BR, mode_problem

    from repro.core.cpapr import effective_mode_combine
    from repro.core.distributed import (
        owner_scatter_wire_bytes,
        sharded_combine_bytes,
    )
    from repro.core.layout import owner_partition

    _, _, _, _, _, _, sl, _, _ = mode_problem("uniform", 0, 4)
    opart = owner_partition(sl)
    assert sharded_combine_bytes(sl, RANK, itemsize=8) == \
        2 * sharded_combine_bytes(sl, RANK, itemsize=4)
    assert owner_scatter_wire_bytes(opart, RANK, itemsize=8) == \
        2 * owner_scatter_wire_bytes(opart, RANK, itemsize=4)
    # the picker itself is scale-invariant, so threading itemsize must
    # never *change* a decision — only the byte accounting
    for itemsize in (2, 4, 8):
        assert effective_mode_combine("auto", "sharded", sl, RANK,
                                      itemsize=itemsize) == \
            effective_mode_combine("auto", "sharded", sl, RANK)


ITEMSIZE_HLO_SCRIPT = """
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.distributed import (_phi_sharded_buf, make_phi_mesh,
                                    sharded_combine_bytes)
from repro.core.phi import expand_to_shards
from repro.perf.hlo import collective_stats
import test_conformance as tc

S = jax.device_count()
assert S == {devices}, S
mesh = make_phi_mesh(S)
t, kt, mv, pi, b, base, sl, pig, vals_sh = tc.mode_problem("uniform", 0, S)
for itemsize, dt in ((4, jnp.float32), (2, jnp.bfloat16)):
    vals_c = jnp.asarray(np.asarray(mv.sorted_vals), dt)
    pi_c = jnp.asarray(np.asarray(pi), dt)
    b_c = jnp.asarray(np.asarray(b), dt)
    vals_es, pi_es = expand_to_shards(sl, vals_c, pi_c)
    txt = _phi_sharded_buf.lower(sl, vals_es, pi_es, b_c, 1e-10, mesh,
                                 "blocked").compile().as_text()
    cs = collective_stats(txt, n_participants=S)
    wire = cs.by_kind_wire["all-reduce"]
    # XLA promotes sub-f32 all-reduces to the f32 accumulator, so the
    # collective itemsize clamps at 4 — the model must use the combine
    # operand's dtype, not blindly the element tier's
    model = 2.0 * (S - 1) / S * sharded_combine_bytes(
        sl, tc.RANK, max(itemsize, 4))
    assert abs(wire - model) <= 0.1 * model, (itemsize, wire, model)
    if itemsize < 4:
        naive = 2.0 * (S - 1) / S * sharded_combine_bytes(sl, tc.RANK,
                                                          itemsize)
        assert wire > 1.5 * naive, (wire, naive)  # promotion is real
    print("itemsize", itemsize, "wire", wire, "model", model)
print("ITEMSIZE_OK")
"""


@pytest.mark.parametrize("devices", [2, 4])
def test_psum_wire_bytes_track_itemsize_in_hlo(devices):
    """Measured HLO all-reduce wire bytes track the element itemsize:
    the bf16 combine moves half the f32 bytes and matches the
    itemsize=2 model (the old model hardcoded 4-byte elements, so any
    non-f32 tier was accounted 2x wrong)."""
    if not can_force_host_devices():
        pytest.skip("host-device forcing unavailable on this backend")
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
    )
    out = subprocess.run(
        [sys.executable, "-c", ITEMSIZE_HLO_SCRIPT.format(devices=devices)],
        env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ITEMSIZE_OK" in out.stdout
