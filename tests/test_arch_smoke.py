"""Per-architecture smoke tests (assignment requirement).

Every assigned arch gets a REDUCED same-family config and runs one
forward + one train step + the prefill/decode serve path on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only by
the AOT dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import ARCHS, reduced
from repro.models.api import build_model
from repro.train.optimizer import make_optimizer
from repro.train.step import init_state, make_train_step

SHAPE = ShapeConfig("smoke", 32, 2, "train")
ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(ARCHS[name])
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_no_nan(built, name):
    cfg, model, params = built(name)
    batch = model.make_batch(jax.random.PRNGKey(1), SHAPE)
    hidden = model.forward(params, batch)
    assert hidden.shape[0] == 2 and hidden.shape[-1] == cfg.d_model
    assert not bool(jnp.isnan(hidden.astype(jnp.float32)).any())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_decreases_nothing_nan(built, name):
    cfg, model, params = built(name)
    opt = make_optimizer(cfg.optimizer, lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    state = {"params": params, "opt": opt.init(params)}
    batch = model.make_batch(jax.random.PRNGKey(2), SHAPE)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)  # same batch twice: loss must drop
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])
    assert np.isfinite(float(m2["grad_norm"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_no_nan(built, name):
    cfg, model, params = built(name)
    batch = model.make_batch(jax.random.PRNGKey(3), SHAPE)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    logits, caches = model.prefill(params, pre)
    assert logits.shape == (2, cfg.vocab_pad)
    assert not bool(jnp.isnan(logits).any())
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, caches = model.decode_step(params, caches, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ["olmo-1b", "mamba2-1.3b",
                                  "recurrentgemma-9b", "h2o-danube-1.8b"])
def test_decode_matches_teacher_forcing(built, name):
    """Greedy decode logits == teacher-forced forward logits at the same
    positions (cache correctness), for each cache family."""
    cfg, model, params = built(name)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, cfg.vocab)
    # teacher-forced: hidden for the full sequence
    hidden = model.forward(params, {"tokens": jnp.pad(tokens, ((0, 0), (0, 1)))})
    w = params["embed"].T
    tf_logits = jnp.einsum("bsd,dv->bsv", hidden, w,
                           preferred_element_type=jnp.float32)
    # incremental: prefill 8, decode 4
    lp, caches = model.prefill(params, {"tokens": tokens[:, :8]}, cache_len=12)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(tf_logits[:, 7]),
                               rtol=2e-2, atol=2e-2)
    for i in range(8, 12):
        ld, caches = model.decode_step(params, caches, tokens[:, i:i + 1])
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(tf_logits[:, i]), rtol=2e-2, atol=2e-2)


def test_vocab_padding_is_masked(built):
    """Loss must ignore vocab-padding logits entirely."""
    cfg, model, params = built("mamba2-1.3b")  # vocab 50280 -> padded
    assert reduced(ARCHS["mamba2-1.3b"]).vocab_pad % 16 == 0
    batch = model.make_batch(jax.random.PRNGKey(5), SHAPE)
    loss = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_param_specs_build(name):
    """Full configs build abstract parameter trees with sane param counts."""
    from repro.models.params import count_params

    cfg = ARCHS[name]
    model = build_model(cfg)
    n = count_params(model.param_specs())
    approx = cfg.n_params()
    assert 0.85 * approx < n < 1.2 * approx, (n, approx)
