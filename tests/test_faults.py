"""Fault-injection recovery matrix (PR 6 tentpole receipt).

Every injected fault — NaN factors, kernel/compile failures, simulated
OOM, shard-assignment fingerprint mismatches, corrupted checkpoints,
poisoned autotune caches — must still end in a *converged* CP-APR solve
whose factors satisfy the dense f64 KKT oracle, with the recovery path
recorded in ``CPAPRResult.recoveries`` instead of a crash.

The CI leg runs this file at 1 and 2 forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``); the sharded
rows use a real mesh when multiple devices exist and the emulated
sharded path otherwise, so the matrix is device-count portable.
"""
import functools
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CPAPRConfig, cpapr_mu, cp_als
from repro.core.policy import PhiPolicy
from repro.core.pi import pi_rows
from repro.core.sparse_tensor import random_poisson_tensor, sort_mode
from repro.perf.autotune import Autotuner
from repro.testing import faults

from conftest import dense_phi_reference

RANK = 4
TOL = 5e-2  # loose outer tolerance: every matrix row must *converge*
SWEEPS = 60  # the clean fixture solve converges in ~35 sweeps at TOL
# small blocks so the fixture modes really shard (>= 4 row blocks)
PB = PhiPolicy(strategy="blocked", block_nnz=64, block_rows=4)


@functools.lru_cache(maxsize=None)
def fixture():
    t, _ = random_poisson_tensor(jax.random.PRNGKey(0), (40, 30, 25),
                                 nnz=1500, rank=RANK)
    return t


def _mesh_or_none(n_shards: int):
    if jax.device_count() >= n_shards:
        from repro.core.distributed import make_phi_mesh

        return make_phi_mesh(n_shards)
    return None


def dense_kkt(t, kt):
    """Worst per-mode KKT violation, dense f64 oracle."""
    worst = 0.0
    for n in range(t.ndim):
        mv = sort_mode(t, n)
        pi = pi_rows(mv.sorted_idx, kt.factors, n)
        b = np.asarray(kt.factors[n] * kt.lam[None, :], np.float64)
        phi = dense_phi_reference(mv.rows, mv.sorted_vals, pi, b, mv.n_rows)
        worst = max(worst, float(np.max(np.abs(np.minimum(b, 1.0 - phi)))))
    return worst


# ---------------------------------------------------------------------------
# The fault x strategy registry.  Each row: solver config, a fault
# context-manager factory, and the RecoveryEvent kind the run must record.
# ---------------------------------------------------------------------------

MATRIX = {
    "nan-segment": dict(
        cfg=dict(strategy="segment"),
        fault=lambda: faults.inject_nan(mode=1, outer=2),
        kind="nan_guard"),
    "nan-pallas": dict(
        cfg=dict(strategy="pallas", policy=PB),
        fault=lambda: faults.inject_nan(mode=0, outer=1),
        kind="nan_guard"),
    "nan-sharded-rs": dict(
        cfg=dict(strategy="sharded", n_shards=2, combine="reduce_scatter",
                 policy=PB),
        fault=lambda: faults.inject_nan(mode=0, outer=1),
        kind="nan_guard"),
    "nan-repeated": dict(
        # three consecutive hits on the same mode: the kappa ladder must
        # escalate past the plain-retry rung and still converge
        cfg=dict(strategy="segment"),
        fault=lambda: faults.inject_nan(mode=0, outer=None, times=3),
        kind="nan_guard"),
    "kernel-pallas": dict(
        cfg=dict(strategy="pallas", policy=PB),
        fault=lambda: faults.fail_strategy(strategy="pallas"),
        kind="demote_kernel"),
    "kernel-sharded-local-pallas": dict(
        cfg=dict(strategy="sharded", n_shards=2, policy=PB),
        fault=lambda: faults.fail_strategy(strategy="sharded"),
        kind="demote_kernel"),
    "oom-sharded": dict(
        cfg=dict(strategy="sharded", n_shards=4, policy=PB),
        fault=lambda: faults.fail_oom(min_shards=3),
        kind="demote_oom"),
    "oom-to-single-device": dict(
        # unbounded OOM: the ladder must walk 4 -> 2 -> single-device
        cfg=dict(strategy="sharded", n_shards=4, policy=PB),
        fault=lambda: faults.fail_oom(min_shards=2),
        kind="demote_oom"),
    "fingerprint-rs": dict(
        cfg=dict(strategy="sharded", n_shards=2, combine="reduce_scatter",
                 policy=PB),
        fault=lambda: faults.fail_fingerprint(),
        kind="demote_fingerprint"),
}


@pytest.mark.parametrize("name", sorted(MATRIX))
def test_fault_matrix_converges_to_oracle(name):
    row = MATRIX[name]
    t = fixture()
    cfg = CPAPRConfig(rank=RANK, max_outer=SWEEPS, tol=TOL, track_loglik=True,
                      **row["cfg"])
    with row["fault"]():
        res = cpapr_mu(t, RANK, config=cfg)
    assert res.converged, (name, res.kkt_history[-5:])
    kinds = [e.kind for e in (res.recoveries or [])]
    assert row["kind"] in kinds, (name, kinds)
    # float32 strategies stop at the first sweep whose f32 KKT <= TOL;
    # the f64 oracle on the same factors can sit slightly above it
    assert dense_kkt(t, res.ktensor) <= TOL * 1.5, name
    assert all(np.isfinite(res.loglik_history))


def test_fault_matrix_on_real_mesh():
    """Sharded rows again, on an actual jax mesh when the process has
    more than one device (the CI 2-device leg); skipped at 1 device."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    t = fixture()
    mesh = _mesh_or_none(2)
    for fault, kind in [
        (faults.inject_nan(mode=0, outer=1), "nan_guard"),
        (faults.fail_fingerprint(), "demote_fingerprint"),
    ]:
        cfg = CPAPRConfig(rank=RANK, max_outer=SWEEPS, tol=TOL,
                          strategy="sharded", n_shards=2, mesh=mesh,
                          combine="reduce_scatter", policy=PB)
        with fault:
            res = cpapr_mu(t, RANK, config=cfg)
        assert res.converged
        assert kind in [e.kind for e in res.recoveries]
        assert dense_kkt(t, res.ktensor) <= TOL * 1.5


def test_unclassifiable_fault_propagates():
    """The ladder only eats failures it can classify — anything else
    (here a KilledError) must surface to the caller unchanged."""
    t = fixture()
    with pytest.raises(faults.KilledError):
        with faults.kill_at_sweep(2):
            cpapr_mu(t, RANK, config=CPAPRConfig(rank=RANK, max_outer=5,
                                                 strategy="segment"))


def test_guard_exhaustion_raises():
    """A fault that reinjects NaN on every retry must exhaust the kappa
    ladder and raise FloatingPointError, not loop forever."""
    t = fixture()
    cfg = CPAPRConfig(rank=RANK, max_outer=5, strategy="segment",
                      guard_retries=2)
    with pytest.raises(FloatingPointError, match=r"mode\(s\) \[0\]"):
        with faults.inject_nan(mode=0, outer=None, times=None):
            cpapr_mu(t, RANK, config=cfg)


def test_guard_off_lets_nan_through():
    """guard=False restores the old behaviour (receipt that the guard is
    doing the work, not some other path)."""
    t = fixture()
    cfg = CPAPRConfig(rank=RANK, max_outer=3, strategy="segment",
                      guard=False, track_loglik=False)
    with faults.inject_nan(mode=0, outer=1):
        res = cpapr_mu(t, RANK, config=cfg)
    assert not bool(jnp.all(jnp.isfinite(res.ktensor.factors[0])))
    assert res.recoveries is None


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------


def _ck_cfg(ck, **kw):
    base = dict(rank=RANK, max_outer=6, tol=0.0, strategy="sharded",
                n_shards=2, combine="reduce_scatter", policy=PB,
                rebalance_every=2, checkpoint_every=2, checkpoint_path=ck)
    base.update(kw)
    return CPAPRConfig(**base)


def test_kill_and_resume_is_bitwise(tmp_path):
    """Kill at sweep 5, resume from the sweep-4 checkpoint: factors,
    lambda and every history are bitwise the uninterrupted run's."""
    t = fixture()
    ck = str(tmp_path / "ck.npz")
    ref = cpapr_mu(t, RANK, config=_ck_cfg(None, checkpoint_every=0,
                                           checkpoint_path=None))
    with pytest.raises(faults.KilledError):
        with faults.kill_at_sweep(5):
            cpapr_mu(t, RANK, config=_ck_cfg(ck))
    res = cpapr_mu(t, RANK, config=_ck_cfg(ck), resume_from=ck)
    assert res.n_outer == ref.n_outer
    for a, b in zip(ref.ktensor.factors, res.ktensor.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ref.ktensor.lam),
                                  np.asarray(res.ktensor.lam))
    assert ref.kkt_history == res.kkt_history
    assert ref.loglik_history == res.loglik_history
    assert ref.inner_iters == res.inner_iters
    assert [e.kind for e in res.recoveries] == ["resume"]


# the dense-tier and grid rows of the resume contract: the checkpoint
# must persist enough per-mode state (strategy list, shard bounds, grid
# shapes) that the resumed solve rebuilds *identical* mode layouts
# instead of defaulting them — receipt is bitwise equality with the
# uninterrupted run, which no re-defaulted strategy could produce
RESUME_TIERS = {
    "dense": dict(strategy="dense", n_shards=None, combine="auto",
                  rebalance_every=0),
    "grid-2x2": dict(strategy="grid", n_shards=4, grid_shape=(2, 2),
                     combine="reduce_scatter", rebalance_every=0),
    "grid-4x1": dict(strategy="grid", n_shards=4, grid_shape=(4, 1),
                     combine="auto", rebalance_every=0),
}


@pytest.mark.parametrize("tier", sorted(RESUME_TIERS))
def test_kill_and_resume_bitwise_dense_and_grid(tmp_path, tier):
    """Kill-and-resume round trip for the dense tier and for 2-D grid
    modes: factors, lambda and every history bitwise the uninterrupted
    run's."""
    t = fixture()
    kw = RESUME_TIERS[tier]
    ck = str(tmp_path / "ck.npz")
    ref = cpapr_mu(t, RANK, config=_ck_cfg(None, checkpoint_every=0,
                                           checkpoint_path=None, **kw))
    with pytest.raises(faults.KilledError):
        with faults.kill_at_sweep(5):
            cpapr_mu(t, RANK, config=_ck_cfg(ck, **kw))
    res = cpapr_mu(t, RANK, config=_ck_cfg(ck, **kw), resume_from=ck)
    assert res.n_outer == ref.n_outer
    for a, b in zip(ref.ktensor.factors, res.ktensor.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ref.ktensor.lam),
                                  np.asarray(res.ktensor.lam))
    assert ref.kkt_history == res.kkt_history
    assert ref.inner_iters == res.inner_iters
    assert [e.kind for e in res.recoveries] == ["resume"]


@pytest.mark.parametrize("kind", ["flip", "truncate", "magic"])
def test_corrupt_checkpoint_quarantined_and_solve_restarts(tmp_path, kind):
    t = fixture()
    ck = str(tmp_path / "ck.npz")
    cfg = _ck_cfg(ck, max_outer=4)
    cpapr_mu(t, RANK, config=cfg)
    faults.corrupt_checkpoint(ck, kind=kind)
    res = cpapr_mu(t, RANK, config=cfg, resume_from=ck)
    kinds = [e.kind for e in res.recoveries]
    assert kinds[0] == "checkpoint_corrupt" and "resume" not in kinds
    assert os.path.exists(ck + ".corrupt")
    # fresh start wrote new valid checkpoints at the original path
    assert os.path.exists(ck)
    ref = cpapr_mu(t, RANK, config=_ck_cfg(None, max_outer=4,
                                           checkpoint_every=0,
                                           checkpoint_path=None))
    assert ref.kkt_history == res.kkt_history


def test_fingerprint_mismatch_rejected(tmp_path):
    """A checkpoint from a different problem/config must not resume."""
    t = fixture()
    ck = str(tmp_path / "ck.npz")
    cpapr_mu(t, RANK, config=_ck_cfg(ck, max_outer=4))
    other = CPAPRConfig(rank=RANK, max_outer=4, tol=1e-9,  # different tol
                        strategy="segment", checkpoint_every=0)
    res = cpapr_mu(t, RANK, config=other, resume_from=ck)
    kinds = [e.kind for e in res.recoveries]
    assert kinds == ["checkpoint_corrupt"]
    assert "fingerprint" in res.recoveries[0].detail["error"]


def test_resume_after_fault_preserves_recovery_log(tmp_path):
    """Recoveries taken before the kill survive the checkpoint and are
    prepended to the resumed run's log."""
    t = fixture()
    ck = str(tmp_path / "ck.npz")
    cfg = _ck_cfg(ck, strategy="pallas", n_shards=None, combine="auto",
                  rebalance_every=0)
    with pytest.raises(faults.KilledError):
        with faults.fail_strategy(strategy="pallas"), faults.kill_at_sweep(5):
            cpapr_mu(t, RANK, config=cfg)
    res = cpapr_mu(t, RANK, config=cfg, resume_from=ck)
    kinds = [e.kind for e in res.recoveries]
    assert kinds[0] == "demote_kernel" and "resume" in kinds


# ---------------------------------------------------------------------------
# Poisoned autotune cache
# ---------------------------------------------------------------------------


def test_poisoned_autotune_demotes_and_converges(tmp_path):
    t = fixture()
    tuner = Autotuner(cache_path=str(tmp_path / "cache.json"), measure=False)
    mv0 = sort_mode(t, 0)
    faults.poison_autotune(tuner, mv0, RANK, strategy="warpspeed",
                           shape=t.shape)
    res = cpapr_mu(t, RANK, config=CPAPRConfig(
        rank=RANK, max_outer=SWEEPS, tol=TOL, policy="auto", autotuner=tuner))
    assert res.converged
    kinds = [e.kind for e in res.recoveries]
    assert "demote_policy" in kinds
    assert dense_kkt(t, res.ktensor) <= TOL * 1.5


# ---------------------------------------------------------------------------
# CP-ALS rides the same ladder
# ---------------------------------------------------------------------------


def test_cpals_kernel_fault_demotes_and_matches():
    t = fixture()
    clean_kt, clean_fits = cp_als(t, RANK, n_iters=5, strategy="segment")
    recs = []
    with faults.fail_strategy(strategy="pallas"):
        kt, fits = cp_als(t, RANK, n_iters=5, strategy="pallas", policy=PB,
                          recoveries=recs)
    assert [e.kind for e in recs] == ["demote_kernel"]
    assert abs(fits[-1] - clean_fits[-1]) < 1e-3
