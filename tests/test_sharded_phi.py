"""Multi-device sharded Phi: layout invariants, fused-step equivalence,
collective-byte accounting vs the analytic O(I_n * R) bound, and the
warned single-device fallbacks.  (Cross-strategy oracle conformance —
including the reduce-scatter combine — lives in the registry-driven
tests/test_conformance.py.)"""
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.core import (
    cpapr_mu,
    CPAPRConfig,
    phi_from_rows,
    phi_mu_step,
    sort_mode,
)
from repro.core.layout import build_blocked_layout, shard_blocked_layout
from repro.core.phi import expand_to_shards
from repro.core.pi import pi_rows
from repro.core.policy import PhiPolicy

from conftest import dense_phi_reference

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mode_problem(small_tensor, mode=0, bn=64, br=8):
    t, kt = small_tensor
    mv = sort_mode(t, mode)
    pi = pi_rows(mv.sorted_idx, kt.factors, mode)
    b = kt.factors[mode] * kt.lam[None, :]
    base = build_blocked_layout(np.asarray(mv.rows), mv.n_rows, bn, br)
    return mv, pi, b, base


# ---------------------------------------------------------------------------
# Sharded layout invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
@pytest.mark.parametrize("bn,br", [(64, 8), (32, 4)])
def test_sharded_layout_partition_invariants(small_tensor, n_shards, bn, br):
    """Shards partition the nonzeros; row-block ranges are contiguous and
    disjoint; per-shard arrays are uniform; grid_rb stays non-decreasing."""
    mv, _, _, _ = _mode_problem(small_tensor)
    base = build_blocked_layout(np.asarray(mv.rows), mv.n_rows, bn, br)
    sl = shard_blocked_layout(base, n_shards)
    assert sl.n_shards == n_shards
    # contiguous disjoint row-block cover
    assert int(sl.rb_start[0]) == 0
    np.testing.assert_array_equal(
        sl.rb_start[1:], sl.rb_start[:-1] + sl.rb_count[:-1]
    )
    assert int(sl.rb_start[-1] + sl.rb_count[-1]) == base.n_row_blocks
    assert np.all(sl.rb_count >= 1)
    # every nonzero appears exactly once across all shards' valid slots
    gathered = np.sort(sl.gather[sl.valid])
    np.testing.assert_array_equal(gathered, np.arange(mv.nnz))
    assert int(sl.shard_nnz.sum()) == mv.nnz
    # uniform shapes, local grid_rb in range and non-decreasing
    assert sl.gather.shape == (n_shards, sl.n_grid_shard * bn)
    assert sl.grid_rb.shape == (n_shards, sl.n_grid_shard)
    assert np.all(sl.grid_rb >= 0) and np.all(sl.grid_rb < sl.n_rb_shard)
    assert np.all(np.diff(sl.grid_rb, axis=1) >= 0)
    # every local row block of every shard is visited at least once
    for s in range(n_shards):
        assert set(sl.grid_rb[s].tolist()) == set(range(sl.n_rb_shard))
    # valid slots land in their shard's global row range
    for s in range(n_shards):
        rows_of_slot = (
            (sl.rb_start[s] + np.repeat(sl.grid_rb[s], bn)) * br
            + sl.local_rows[s]
        )
        v = sl.valid[s]
        np.testing.assert_array_equal(
            rows_of_slot[v], np.asarray(mv.rows)[sl.gather[s][v]]
        )
    assert sl.buf_rows >= base.n_rows_pad


def test_shard_layout_rejects_too_many_shards(small_tensor):
    mv, _, _, _ = _mode_problem(small_tensor)
    base = build_blocked_layout(np.asarray(mv.rows), mv.n_rows, 64, 256)
    assert base.n_row_blocks == 1
    with pytest.raises(ValueError, match="n_row_blocks"):
        shard_blocked_layout(base, 2)


# ---------------------------------------------------------------------------
# Fused-step equivalence (cross-strategy oracle conformance now lives in
# tests/test_conformance.py — one registry table instead of per-file loops)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 3, 4])
@pytest.mark.parametrize("local_strategy", ["blocked", "pallas"])
def test_sharded_phi_mu_step_matches_unfused(small_tensor, n_shards,
                                             local_strategy):
    """Fused sharded (B', viol) == unfused scatter composition, for both
    local compute flavours (jnp emulation and the Pallas kernel)."""
    mv, pi, b, base = _mode_problem(small_tensor)
    sl = shard_blocked_layout(base, n_shards)
    tol = 1e-4
    phi = phi_from_rows(mv.rows, mv.sorted_vals, pi, b, mv.n_rows,
                        strategy="scatter")
    viol_ref = np.max(np.abs(np.minimum(np.asarray(b), 1.0 - np.asarray(phi))))
    b_ref = np.asarray(b) * np.asarray(phi) if viol_ref > tol else np.asarray(b)
    out_b, out_v = phi_mu_step(mv.rows, mv.sorted_vals, pi, b, mv.n_rows,
                               tol=tol, strategy="sharded", layout=sl,
                               local_strategy=local_strategy)
    np.testing.assert_allclose(float(out_v), viol_ref, rtol=3e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_b), b_ref, rtol=3e-5, atol=1e-5)


def test_sharded_pre_expanded_inputs_match(small_tensor):
    """Hoisted expand_to_shards arrays give the same answer as re-expansion."""
    mv, pi, b, base = _mode_problem(small_tensor)
    sl = shard_blocked_layout(base, 3)
    vals_es, pi_es = expand_to_shards(sl, mv.sorted_vals, pi)
    a = phi_from_rows(mv.rows, mv.sorted_vals, pi, b, mv.n_rows,
                      strategy="sharded", layout=sl)
    h = phi_from_rows(mv.rows, mv.sorted_vals, pi, b, mv.n_rows,
                      strategy="sharded", layout=sl,
                      vals_e=vals_es, pi_e=pi_es)
    np.testing.assert_allclose(np.asarray(a), np.asarray(h),
                               rtol=1e-6, atol=1e-7)


def test_cpapr_sharded_matches_segment(small_tensor):
    """Full solver equivalence: sharded strategy == segment strategy."""
    t, _ = small_tensor
    ref = cpapr_mu(t, rank=4, config=CPAPRConfig(
        rank=4, max_outer=3, strategy="segment", track_loglik=False))
    res = cpapr_mu(t, rank=4, config=CPAPRConfig(
        rank=4, max_outer=3, strategy="sharded", n_shards=3,
        track_loglik=False))
    for a, b in zip(ref.ktensor.factors, res.ktensor.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(ref.kkt_history, res.kkt_history, rtol=1e-4)


# ---------------------------------------------------------------------------
# Warned single-device fallbacks (instead of cryptic reshape errors)
# ---------------------------------------------------------------------------


def test_sharded_phi_falls_back_when_too_few_row_blocks(small_tensor,
                                                        monkeypatch):
    """More shards requested than row blocks exist: warn + single-device
    blocked result, never a cryptic reshape error."""
    mv, pi, b, _ = _mode_problem(small_tensor)
    monkeypatch.setattr("repro.core.phi._default_shard_count",
                        lambda mesh: 4096)
    ref = dense_phi_reference(mv.rows, mv.sorted_vals, pi, b, mv.n_rows)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = phi_from_rows(mv.rows, mv.sorted_vals, pi, b, mv.n_rows,
                            strategy="sharded")
        bs, vs = phi_mu_step(mv.rows, mv.sorted_vals, pi, b, mv.n_rows,
                             strategy="sharded")
    assert any("falling back" in str(x.message) for x in w)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=3e-5, atol=1e-5)
    viol = np.max(np.abs(np.minimum(np.asarray(b, np.float64), 1.0 - ref)))
    np.testing.assert_allclose(float(vs), viol, rtol=3e-5, atol=1e-5)
    assert bs.shape == b.shape


def test_cpapr_sharded_falls_back_with_warning(small_tensor):
    t, _ = small_tensor
    cfg = CPAPRConfig(rank=4, max_outer=2, strategy="sharded", n_shards=64,
                      track_loglik=False,
                      policy=PhiPolicy(strategy="blocked", block_nnz=64,
                                       block_rows=256))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res = cpapr_mu(t, rank=4, config=cfg)
    assert any("falling back" in str(x.message) for x in w)
    ref = cpapr_mu(t, rank=4, config=CPAPRConfig(
        rank=4, max_outer=2, strategy="segment", track_loglik=False))
    np.testing.assert_allclose(res.kkt_history, ref.kkt_history, rtol=1e-4)


# ---------------------------------------------------------------------------
# _shard_map compat shim (check_rep -> check_vma rename)
# ---------------------------------------------------------------------------


def test_shard_map_check_kwarg_shim():
    from repro.core.distributed import (
        _check_kwarg,
        _resolve_shard_map,
        _shard_map,
    )

    captured = {}

    def fake_vma(f, *, mesh, in_specs, out_specs, check_vma=True):
        captured["kw"] = ("check_vma", check_vma)
        return f

    def fake_rep(f, *, mesh, in_specs, out_specs, check_rep=True):
        captured["kw"] = ("check_rep", check_rep)
        return f

    assert _check_kwarg(fake_vma) == "check_vma"
    assert _check_kwarg(fake_rep) == "check_rep"
    _shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=(), sm=fake_vma)
    assert captured["kw"] == ("check_vma", False)
    _shard_map(lambda x: x, mesh=None, in_specs=(), out_specs=(), sm=fake_rep)
    assert captured["kw"] == ("check_rep", False)
    # the real jax shard_map resolves and takes one of the two kwargs
    assert _check_kwarg(_resolve_shard_map()) in ("check_vma", "check_rep")


# ---------------------------------------------------------------------------
# Real-mesh equivalence + collective accounting (forced-device subprocesses)
# ---------------------------------------------------------------------------


def _run(script: str, devices: int, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


HLO_SCRIPT = """
import jax, numpy as np
from repro.core.sparse_tensor import random_poisson_tensor, sort_mode
from repro.core.pi import pi_rows
from repro.core.layout import build_blocked_layout, shard_blocked_layout
from repro.core.phi import expand_to_shards
from repro.core.distributed import (_phi_sharded_buf, make_phi_mesh,
                                    sharded_combine_bytes)
from repro.perf.hlo import (collective_stats, allreduce_wire_bytes,
                            phi_combine_wire_bound)

S = jax.device_count()
assert S == 4
t, kt = random_poisson_tensor(jax.random.PRNGKey(0), (40, 30, 25),
                              nnz=1500, rank=4)
mv = sort_mode(t, 0)
pi = pi_rows(mv.sorted_idx, kt.factors, 0)
b = kt.factors[0] * kt.lam[None, :]
base = build_blocked_layout(np.asarray(mv.rows), mv.n_rows, 64, 8)
sl = shard_blocked_layout(base, S)
mesh = make_phi_mesh(S)
vals_es, pi_es = expand_to_shards(sl, mv.sorted_vals, pi)
txt = _phi_sharded_buf.lower(sl, vals_es, pi_es, b, 1e-10, mesh,
                             "blocked").compile().as_text()
cs = collective_stats(txt, n_participants=S)
assert cs.by_kind_count.get("all-reduce", 0) >= 1, cs.by_kind_count
expected = allreduce_wire_bytes(sharded_combine_bytes(sl, 4), S)
bound = phi_combine_wire_bound(mv.n_rows, 4, S, block_rows=8)
print("wire", cs.wire_bytes, "expected", expected, "bound", bound)
# the measured combine must match the psum of the combine buffer ...
assert abs(cs.wire_bytes - expected) <= 0.1 * expected, (cs.wire_bytes,
                                                         expected)
# ... and stay under the analytic O(I_n * R) bound
assert 0 < cs.wire_bytes <= bound, (cs.wire_bytes, bound)
print("HLO_OK")
"""


def test_sharded_combine_collective_bytes_within_bound():
    """repro.perf.hlo accounting of the sharded Phi combine: exactly the
    psum of the combine buffer, under the analytic O(I_n * R) bound."""
    assert "HLO_OK" in _run(HLO_SCRIPT, devices=4)


DIST_FALLBACK_SCRIPT = """
import warnings
import jax, numpy as np
from repro.core import cpapr_mu, CPAPRConfig, random_poisson_tensor, \
    random_ktensor
from repro.core.distributed import DistCPAPRConfig, dist_cpapr_mu
t, _ = random_poisson_tensor(jax.random.PRNGKey(0), (24, 18, 15),
                             nnz=900, rank=3)
init = random_ktensor(jax.random.PRNGKey(1), t.shape, 3)
mesh = jax.make_mesh((2, 2), ("data", "model"))
with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    # rank 3 is not divisible by the model axis (2): must warn + fall back
    kt_d, hist = dist_cpapr_mu(t, 3, mesh, init=init,
                               config=DistCPAPRConfig(rank=3, max_outer=2,
                                                      max_inner=3))
assert any("falling back" in str(x.message) for x in w), \
    [str(x.message) for x in w]
res = cpapr_mu(t, 3, init=init,
               config=CPAPRConfig(rank=3, max_outer=2, max_inner=3,
                                  track_loglik=False))
for fd, fs in zip(kt_d.factors, res.ktensor.factors):
    np.testing.assert_allclose(np.asarray(fd), np.asarray(fs),
                               rtol=2e-4, atol=2e-5)
print("FALLBACK_OK")
"""


def test_dist_cpapr_invalid_mesh_falls_back_single_device():
    """dist_cpapr_mu with an unshardable mesh (rank % model != 0) warns and
    falls back to one device instead of dying in a reshape."""
    assert "FALLBACK_OK" in _run(DIST_FALLBACK_SCRIPT, devices=4)


# ---------------------------------------------------------------------------
# In-process multi-device coverage (auto-skipped on 1 device)
# ---------------------------------------------------------------------------


@pytest.mark.multidevice
def test_mesh_matches_emulation_in_process(small_tensor):
    """Real shard_map + psum == the one-device emulation, bitwise-close."""
    from repro.core.distributed import make_phi_mesh, phi_sharded

    mv, pi, b, base = _mode_problem(small_tensor)
    n = min(jax.device_count(), base.n_row_blocks)
    sl = shard_blocked_layout(base, n)
    vals_es, pi_es = expand_to_shards(sl, mv.sorted_vals, pi)
    emu = phi_sharded(sl, vals_es, pi_es, b)
    real = phi_sharded(sl, vals_es, pi_es, b, mesh=make_phi_mesh(n))
    np.testing.assert_allclose(np.asarray(real), np.asarray(emu),
                               rtol=1e-6, atol=1e-7)
